"""Chaos benchmark: a seeded fault schedule against a live service.

  REPRO_FAULTS="seed=...;..." PYTHONPATH=src \\
      python -m benchmarks.chaos_bench [--fast]

Drives a mixed concurrent workload (price sweeps, Monte-Carlo risk,
ranking, search, raw specs, tiny-deadline requests, one deliberately
invalid request) through a PricingService while the
:mod:`repro.resilience` fault injector fires every fault kind it knows:
fused-dispatch exceptions, a tick stall long enough to trip the
watchdog, poisoned candidate rows, admission floods, and forced
recompiles.  The schedule comes from ``REPRO_FAULTS`` when set (the CI
chaos-smoke job sets it) and falls back to :data:`DEFAULT_FAULTS`.

Asserts (the degraded-mode guarantees of README "Failure handling"):
  * every response is ok or carries a **typed** error envelope — zero
    untyped/internal errors, zero exceptions escaping the tick loop;
  * zero cross-request contamination: every ok price/mc_risk row is
    bit-exact against the oracle its provenance names — the fused
    evaluator for fused rows, float32 casts of the legacy host-packing
    evaluator for degraded rows;
  * exactly one watchdog trip AND one flight recording per induced
    stall;
  * every fault kind in the schedule actually fired (a chaos run that
    quietly tested nothing must fail).

A second, separate sub-run injects the ``crash`` fault kind mid-search
(the moral equivalent of SIGKILL at a tick boundary), restarts the
service over the same durability directory, and proves the journal
replay recovers everything: zero requests lost, the resumed search
bit-exact against the uninterrupted ``portfolio_search`` oracle, and
recovery latency reported.

Reports recovery latency (circuit-breaker open time) and degraded-mode
throughput (fallback rows/s), and writes BENCH_chaos.json for
scripts/check_bench_regression.py.
"""
import argparse
import asyncio
import os
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro.dse import ChunkedEvaluator, portfolio_search
from repro.resilience import FaultInjector
from repro.service import (DEADLINE_EXCEEDED, DurabilityConfig,
                           INVALID_REQUEST, McSpec,
                           MCRiskRequest, NUMERICAL_ERROR, PriceRequest,
                           PriceSystemsRequest, PricingService, QUEUE_FULL,
                           RankRequest, RequestJournal, SearchRequest,
                           SearchWarmup, ServiceConfig, SHUTTING_DOWN)

from .common import emit, write_bench_json
from .dse_bench import SPACE

# The closed set a client may dispatch on; anything else is a bug.
TYPED_CODES = {QUEUE_FULL, INVALID_REQUEST, DEADLINE_EXCEEDED,
               NUMERICAL_ERROR, SHUTTING_DOWN}

# Every kind enabled, tuned so the seeded schedule exercises each one
# within a --fast run: one long stall (watchdog food), a steady diet of
# dispatch errors (breaker + fallback), a few poisoned rows, floods and
# recompiles.
DEFAULT_FAULTS = ("seed=1337;dispatch_error:p=0.35;stall:p=1.0,ms=1200,n=1;"
                  "poison:p=0.3,n=4;flood:p=0.3,n=3;recompile:p=0.4,n=2")

MC = dict(draws=32, quantiles=(0.5, 0.9), seed=0)


def _requests(rng: np.random.Generator, size: int, fast: bool):
    """The mixed chaos diet: (request, parity_kind) pairs.

    ``parity_kind`` says which oracle (if any) can check the response's
    rows bit-exactly: "price", "mc" or None."""
    sweep = 64 if fast else 128
    n_sweeps = 4 if fast else 8
    out = []
    for _ in range(n_sweeps):
        out.append((PriceRequest(
            indices=rng.integers(0, size, sweep).tolist()), "price"))
        out.append((PriceRequest(
            indices=rng.integers(0, size, 4).tolist()), "price"))
    out.append((MCRiskRequest(
        indices=rng.integers(0, size, 32).tolist(),
        mc=McSpec(**MC)), "mc"))
    out.append((RankRequest(
        indices=rng.integers(0, size, 48).tolist(), top_k=5), None))
    out.append((SearchRequest(seed=3, population=16,
                              generations=2 if fast else 4, elite=4), None))
    out.append((PriceSystemsRequest(specs=(
        {"kind": "soc", "name": "soc_a", "area": 250.0,
         "process": "7nm", "quantity": 1e6},)), None))
    # deadlines that cannot realistically be met: must come back as
    # typed deadline_exceeded (or, if the box is absurdly fast, ok)
    for _ in range(2):
        out.append((PriceRequest(
            indices=rng.integers(0, size, sweep).tolist(),
            deadline_ms=0.5), "price"))
    # one deliberately invalid request: NaN area must be rejected at
    # admission, never reach a kernel next to the others
    out.append((PriceSystemsRequest(specs=(
        {"kind": "soc", "name": "broken", "area": float("nan"),
         "process": "7nm", "quantity": 1e6},)), None))
    return out


def _parity_mismatches(resp, idx, kind, fused_ev, legacy_ev) -> int:
    """Count rows of an ok response that match NEITHER provenance
    oracle's value — i.e. contaminated rows."""
    idx = np.asarray(idx, np.int64)
    mask = (resp.degraded_rows if resp.degraded and resp.degraded_rows
            is not None else np.zeros(idx.size, bool))
    if kind == "mc":
        key = jax.random.PRNGKey(MC["seed"])
        fused = fused_ev.evaluate_indices(idx, mc_key=key,
                                          mc_draws=MC["draws"],
                                          mc_quantiles=MC["quantiles"])
        legacy = legacy_ev.evaluate_indices_legacy(
            idx, mc_key=key, mc_draws=MC["draws"],
            mc_quantiles=MC["quantiles"]) if mask.any() else None
    else:
        fused = fused_ev.evaluate_indices(idx)
        legacy = (legacy_ev.evaluate_indices_legacy(idx)
                  if mask.any() else None)
    bad = 0
    for j in range(idx.size):
        src = legacy if mask[j] else fused
        ok = (np.array_equal(resp.result.sku_unit_total[j],
                             src.sku_unit_total[j])
              and resp.result.portfolio_cost[j] == src.portfolio_cost[j])
        if ok and resp.result.risk is not None:
            ok = all(resp.result.risk[k][j] == src.risk[k][j]
                     for k in resp.result.risk)
        bad += not ok
    return bad


# The crash scenario runs as its own sub-run (the main schedule's
# "every enabled kind fired" assertion would otherwise have to wait for
# a crash that, by design, ends the run).  seed=1 p=0.3 first fires at
# fault check 6, so a few generations — and their checkpoints — land
# before the process "dies".
CRASH_FAULTS = "seed=1;crash:p=0.3,n=1"


def _crash_recovery(fast: bool) -> dict:
    """Injected crash mid-search -> restart -> journal replay: measures
    recovery latency and proves the resumed search bit-exact against the
    uninterrupted ``portfolio_search`` oracle with zero lost requests."""
    gens = 8 if fast else 12
    sr = SearchRequest(seed=3, population=16, generations=gens, elite=4)
    rng = np.random.default_rng(7)
    size = SPACE.size()
    prices = [PriceRequest(indices=rng.integers(0, size, 16).tolist())
              for _ in range(3)]
    with tempfile.TemporaryDirectory(prefix="repro_chaos_crash_") as d:
        dcfg = DurabilityConfig(directory=pathlib.Path(d),
                                checkpoint_every=1)
        cfg = ServiceConfig(chunk=32, split=8,
                            warm_search=(SearchWarmup(population=16,
                                                      elite=4),),
                            durability=dcfg)

        async def _main():
            svc = PricingService(SPACE, cfg)
            await svc.start()
            svc.faults = FaultInjector(CRASH_FAULTS)
            first = await asyncio.gather(svc.submit(sr),
                                         *(svc.submit(p) for p in prices))
            crashes = svc.snapshot()["durability"]["crashes"]
            await svc.stop()
            svc.faults = FaultInjector("")
            t0 = time.perf_counter()
            await svc.start()
            replayed = await svc.drain_replayed()
            recovery_s = time.perf_counter() - t0
            await svc.stop()
            return svc, list(first), replayed, recovery_s, crashes

        svc, first, replayed, recovery_s, crashes = asyncio.run(_main())
        untyped = sum(1 for r in first + replayed
                      if not r.ok and r.error.code not in TYPED_CODES)
        search_resp = next((r for r in replayed + first
                            if r.kind == "search" and r.ok), None)
        oracle = portfolio_search(SPACE, jax.random.PRNGKey(3),
                                  population=16, generations=gens, elite=4)
        bitexact = int(
            search_resp is not None
            and search_resp.result.history == oracle.history
            and [c.label for c in search_resp.result.ranked]
            == [c.label for c in oracle.ranked])
        j = RequestJournal(dcfg.journal_dir)
        lost = len(j.replay())
        j.close()
        snap = svc.snapshot()["durability"]
    out = {
        "crash_recovered": int(crashes >= 1),
        "crash_replayed": snap["journal_replayed"],
        "crash_replayed_lost": lost,
        "crash_resume_bitexact": bitexact,
        "crash_checkpoints_restored": snap["checkpoints_restored"],
        "crash_untyped_errors": untyped,
        "crash_recovery_s": recovery_s,
    }
    emit("chaos: crash -> journal replay recovery", [{
        "crashes": crashes, "replayed": out["crash_replayed"],
        "lost": lost, "bitexact": bitexact,
        "ckpt_restored": out["crash_checkpoints_restored"],
        "recovery_s": recovery_s}])
    assert out["crash_recovered"] == 1, "crash fault never fired"
    assert untyped == 0, "crash recovery produced untyped errors"
    assert lost == 0, f"{lost} journaled requests were silently lost"
    assert bitexact == 1, \
        "resumed search is not bit-exact vs the uninterrupted oracle"
    return out


def run(fast: bool = False, clients: int = 6) -> dict:
    spec = os.environ.get("REPRO_FAULTS") or DEFAULT_FAULTS
    faults = FaultInjector(spec)
    assert faults, "chaos bench needs a non-empty fault schedule"
    size = SPACE.size()
    chunk = 32
    cfg = ServiceConfig(
        chunk=chunk, split=8,
        warm_mc=((MC["draws"], MC["quantiles"]),),
        warm_search=(SearchWarmup(population=16, elite=4),),
        max_pending=200_000,
        breaker_cooldown_s=0.2,
        watchdog_timeout_s=0.4)

    # Parity oracles: the fused evaluator for fused-path rows, the
    # legacy host-packing evaluator (f32 casts) for degraded rows.
    fused_ev = ChunkedEvaluator(SPACE, candidates_per_chunk=chunk)
    legacy_ev = ChunkedEvaluator(SPACE, candidates_per_chunk=chunk,
                                 fused=False)

    # Watchdog dumps need a flight dir; use the ambient one (CI sets it)
    # or a scratch dir, restoring the env either way.
    prior_dir = os.environ.get("REPRO_FLIGHT_DIR")
    dump_dir = prior_dir or tempfile.mkdtemp(prefix="repro_chaos_flight_")
    os.environ["REPRO_FLIGHT_DIR"] = dump_dir

    async def _main():
        svc = PricingService(SPACE, cfg)
        svc.faults = faults
        await svc.start()

        async def client(i: int):
            crng = np.random.default_rng(1000 + i)
            out = []
            for req, parity in _requests(crng, size, fast):
                out.append((req, parity, await svc.submit(req)))
            return out

        t0 = time.perf_counter()
        per_client = await asyncio.gather(*(client(i)
                                            for i in range(clients)))
        wall = time.perf_counter() - t0
        await svc.stop()
        return per_client, wall, svc

    try:
        per_client, wall, svc = asyncio.run(_main())
    finally:
        if prior_dir is None:
            os.environ.pop("REPRO_FLIGHT_DIR", None)

    flat = [t for rs in per_client for t in rs]
    untyped, contaminated, by_code = 0, 0, {}
    n_ok = n_degraded = 0
    for req, parity, resp in flat:
        if not resp.ok:
            code = resp.error.code
            by_code[code] = by_code.get(code, 0) + 1
            untyped += code not in TYPED_CODES
            continue
        n_ok += 1
        n_degraded += bool(resp.degraded)
        if parity is not None:
            contaminated += _parity_mismatches(
                resp, req.indices, parity, fused_ev, legacy_ev)

    snap = svc.snapshot()
    res = snap["resilience"]
    fired = res["faults"]["fired"]
    kinds_fired = sorted(k for k, n in fired.items() if n)
    stalls = fired.get("stall", 0)
    # "one recording per induced stall": every stall must trip the
    # watchdog, and every trip must dump exactly once.  Trips may exceed
    # stalls — a forced-recompile fault makes the next tick compile
    # in-line, which legitimately stalls past the timeout too.
    deficit = max(0, stalls - res["watchdog_trips"]) + \
        abs(res["watchdog_dumps"] - res["watchdog_trips"])
    fb_rows, fb_busy = res["fallback_rows"], res["fallback_busy_s"]
    summary = {
        "clients": clients,
        "fault_spec": spec,
        "n_requests": len(flat),
        "n_ok": n_ok,
        "n_degraded_responses": n_degraded,
        "errors_by_code": by_code,
        "untyped_errors": untyped,
        "contaminated_rows": contaminated,
        "loop_errors": res["loop_errors"],
        "faults_injected": res["faults_injected"],
        "fault_kinds_injected": len(kinds_fired),
        "fault_kinds": kinds_fired,
        "stalls_fired": stalls,
        "watchdog_trips": res["watchdog_trips"],
        "watchdog_dumps": res["watchdog_dumps"],
        "stall_dump_deficit": deficit,
        "retries": res["retries"],
        "fallback_ticks": res["fallback_ticks"],
        "fallback_rows": fb_rows,
        "degraded_rows_per_sec": fb_rows / fb_busy if fb_busy else 0.0,
        "breaker_opens": res["breaker"]["opens"],
        "recovery_open_s_total": res["breaker"]["open_s_total"],
        "recovery_last_open_s": res["breaker"]["last_open_s"],
        "deadline_rejected": res["deadline_rejected"],
        "numerical_errors": res["numerical_errors"],
        "wall_s": wall,
        "fast": fast,
        "survived": 1.0,
    }
    emit("chaos: seeded fault schedule", [{
        "requests": summary["n_requests"], "ok": n_ok,
        "degraded": n_degraded, "untyped": untyped,
        "contaminated": contaminated,
        "kinds": "+".join(kinds_fired),
        "fallback_rows_per_sec": summary["degraded_rows_per_sec"],
        "recovery_s": summary["recovery_open_s_total"],
        "loop_errors": summary["loop_errors"]}])
    # crash/restore sub-run: its keys ride the same BENCH_chaos.json so
    # the regression guard pins the recovery invariants too.
    summary.update(_crash_recovery(fast))
    write_bench_json("chaos", summary)

    # -- acceptance --------------------------------------------------------
    assert untyped == 0, \
        f"{untyped} responses carried untyped errors: {by_code}"
    assert contaminated == 0, \
        f"{contaminated} ok rows match neither provenance oracle"
    assert res["loop_errors"] == 0, \
        f"{res['loop_errors']} exceptions escaped a tick into the loop guard"
    assert deficit == 0, \
        (f"stalls={stalls} but trips={res['watchdog_trips']} "
         f"dumps={res['watchdog_dumps']}")
    assert len(kinds_fired) == len(faults.rules), \
        (f"schedule enables {sorted(faults.rules)} but only "
         f"{kinds_fired} fired — retune DEFAULT_FAULTS")
    assert by_code.get(INVALID_REQUEST, 0) >= clients, \
        "the NaN-area request must be rejected as invalid_request"
    print(f"# chaos: survived {len(flat)} requests under "
          f"{'+'.join(kinds_fired)}; {n_degraded} degraded responses, "
          f"0 untyped errors, 0 contaminated rows, "
          f"recovery {summary['recovery_open_s_total']*1e3:.0f} ms total")
    return summary


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller sweeps and searches")
    ap.add_argument("--clients", type=int, default=6)
    args = ap.parse_args()
    run(fast=args.fast, clients=args.clients)


if __name__ == "__main__":
    main()
