"""Hand-scheduled collectives (shard_map) for the cases where GSPMD's
automatic choice is not what a 1000-node deployment wants.

* ``compressed_psum``      — hierarchical gradient reduction: full-
  precision reduce inside a pod, top-k+int8 (error feedback) on the
  cross-pod leg.  Wire bytes drop ~25x on the scarce pod-to-pod links.
* ``flash_decode_shardmap``— sequence-parallel decode attention: each
  device holds a KV-cache shard, computes partial (max, sum, acc) and
  combines with two tiny psums — FlashDecoding's tree-reduction mapped
  onto the TPU mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------------------
# Hierarchical compressed all-reduce
# ---------------------------------------------------------------------------


def _topk_int8_wire(x, k_fraction: float):
    """(values_int8, indices, scale) — what actually crosses the pod link."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * k_fraction))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    scale = jnp.maximum(jnp.abs(kept).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(kept / scale), -127, 127).astype(jnp.int8)
    return q, idx, scale


def compressed_psum(mesh: Mesh, *, pod_axis: str = "pod",
                    inner_axes: Tuple[str, ...] = ("data",),
                    k_fraction: float = 0.05):
    """Build fn(grad (replicated-shape per inner shard), err) -> (g, err).

    Protocol per tensor:
      1. psum over the intra-pod axes (full precision, fast ICI);
      2. add error-feedback residual; top-k+int8 encode;
      3. psum the DENSE reconstruction over the pod axis — on a real
         wire the (int8 values, indices) pairs are exchanged; the dense
         psum here is the semantics-equivalent single-process stand-in,
         while wire bytes are accounted analytically (see
         optim.compression.compressed_bytes);
      4. new residual = input - reconstruction (stays local).
    """

    def reduce_one(g, err):
        g = jax.lax.psum(g, inner_axes)
        g_in = g + err
        q, idx, scale = _topk_int8_wire(g_in, k_fraction)
        recon = jnp.zeros_like(g_in.reshape(-1)).at[idx].set(
            q.astype(g_in.dtype) * scale).reshape(g_in.shape)
        g_out = jax.lax.psum(recon, pod_axis) / 1.0
        new_err = g_in - recon
        return g_out, new_err

    def fn(grads, errs):
        pairs = jax.tree_util.tree_map(reduce_one, grads, errs)
        new_g = jax.tree_util.tree_map(
            lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree_util.tree_map(
            lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_e

    return fn


# ---------------------------------------------------------------------------
# Sequence-parallel flash decode
# ---------------------------------------------------------------------------


def flash_decode_shardmap(mesh: Mesh, seq_axis: str = "model"):
    """fn(q (B,H,D), k (B,T,H,D), v (B,T,H,D)) with T sharded on seq_axis.

    Each shard computes its local (m, l, acc); two psum_scatter-free
    psums of (B,H) scalars + (B,H,D) combine the partial softmaxes:
    out = sum_i exp(m_i - m) * acc_i / sum_i exp(m_i - m) * l_i.
    """

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, None, None), P(None, seq_axis, None, None),
                  P(None, seq_axis, None, None)),
        out_specs=P(None, None, None), check_rep=False)
    def fn(q, k, v):
        scale = q.shape[-1] ** -0.5
        s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        m_loc = s.max(axis=-1)                          # (B,H)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = p.sum(axis=-1)
        acc = jnp.einsum("bht,bthd->bhd", p, v.astype(jnp.float32))
        m = jax.lax.pmax(m_loc, seq_axis)               # global max
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * corr, seq_axis)
        acc = jax.lax.psum(acc * corr[..., None], seq_axis)
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    return fn
