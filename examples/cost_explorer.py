"""Architecture exploration: sweep the (area x n_chiplets x tech x node)
design space with the vmapped explorer, print the Pareto frontier, and
run the (beyond-paper) differentiable partitioner.

  PYTHONPATH=src python examples/cost_explorer.py
"""
import jax.numpy as jnp

from repro.core import pareto_front, sweep_partitions
from repro.core.gradient import optimize_chiplet_count


def main():
    points = []
    for node in ("14nm", "7nm", "5nm"):
        for integ in ("MCM", "InFO", "2.5D"):
            res = sweep_partitions(node, integ,
                                   areas_mm2=[200, 400, 600, 800],
                                   n_chiplets=[1, 2, 3, 4, 5, 6])
            totals = res["total"]
            for i, a in enumerate(res["areas"]):
                for j, n in enumerate(res["n_chiplets"]):
                    points.append({
                        "node": node, "integ": integ, "area": float(a),
                        "n": int(n), "cost": float(totals[i, j]),
                    })
    # Pareto: cheapest way to buy silicon area
    front = pareto_front(
        [{"x": -p["area"], "y": p["cost"], **p} for p in points], "x", "y")
    print("cost-area Pareto frontier (max area, min cost):")
    for p in front:
        print(f"  {p['area']:5.0f}mm2  ${p['cost']:8.0f}  "
              f"{p['node']} {p['integ']} n={p['n']}")

    print("\ndifferentiable partitioner (relaxed chiplet count):")
    for node in ("7nm", "5nm"):
        r = optimize_chiplet_count(node, "MCM", 800.0)
        print(f"  {node} 800mm2 MCM: n*={r.n_relaxed:.2f} -> "
              f"round {r.n_rounded}, cost ${r.cost_rounded:.0f} "
              f"(SoC ${r.cost_soc:.0f})")


if __name__ == "__main__":
    main()
