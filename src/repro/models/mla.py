"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Keys and values are compressed into a small latent ``c_kv`` (kv_lora_rank)
plus a per-token shared RoPE key; the decode KV cache stores ONLY the
latent (+ rope key), and decoding runs in the compressed space via weight
absorption — the 32k/500k-cache cost win that makes MLA worth modeling.

Train/prefill path decompresses to per-head K/V and reuses the chunked
flash dataflow from ``attention.py``.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .attention import attend_chunked, attend_full, NEG_INF
from .common import ParamSpec, apply_rope, rmsnorm, rmsnorm_spec


def mla_spec(d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             qk_nope: int, qk_rope: int, v_head: int) -> Dict[str, ParamSpec]:
    sp: Dict[str, ParamSpec] = {}
    if q_lora > 0:
        sp["wq_a"] = ParamSpec((d_model, q_lora), ("embed", None))
        sp["q_norm"] = rmsnorm_spec(q_lora)["scale"]
        sp["wq_b"] = ParamSpec((q_lora, n_heads, qk_nope + qk_rope),
                               (None, "heads", None))
    else:
        sp["wq"] = ParamSpec((d_model, n_heads, qk_nope + qk_rope),
                             ("embed", "heads", None))
    sp["wkv_a"] = ParamSpec((d_model, kv_lora + qk_rope), ("embed", None))
    sp["kv_norm"] = rmsnorm_spec(kv_lora)["scale"]
    sp["wkv_b"] = ParamSpec((kv_lora, n_heads, qk_nope + v_head),
                            (None, "heads", None))
    sp["wo"] = ParamSpec((n_heads, v_head, d_model), ("heads", None, "embed"))
    return sp


def _mla_dims(params):
    kv_lora = params["kv_norm"].shape[0]
    n_heads = params["wkv_b"].shape[1]
    qk_rope = params["wkv_a"].shape[1] - kv_lora
    if "wq_b" in params:
        qk_nope = params["wq_b"].shape[2] - qk_rope
    else:
        qk_nope = params["wq"].shape[2] - qk_rope
    v_head = params["wkv_b"].shape[2] - qk_nope
    return kv_lora, n_heads, qk_nope, qk_rope, v_head


def mla_project_q(params, x, positions, rope_theta, qk_nope, qk_rope):
    if "wq_a" in params:
        cq = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
        cq = rmsnorm({"scale": params["q_norm"]}, cq)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = jnp.split(q, [qk_nope], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def mla_compress_kv(params, x, positions, rope_theta, kv_lora):
    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = jnp.split(ckv, [kv_lora], axis=-1)
    c_kv = rmsnorm({"scale": params["kv_norm"]}, c_kv)
    k_rope = apply_rope(k_rope, positions, rope_theta)  # shared single head
    return c_kv, k_rope


def mla_layer(params, x, positions, *, rope_theta: float = 10000.0,
              impl: str = "chunked", chunk: int = 1024):
    """Train/prefill MLA: decompress and run standard attention."""
    kv_lora, h, qk_nope, qk_rope, v_head = _mla_dims(params)
    q_nope, q_rope = mla_project_q(params, x, positions, rope_theta,
                                   qk_nope, qk_rope)
    c_kv, k_rope = mla_compress_kv(params, x, positions, rope_theta, kv_lora)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b"])
    k_nope, v = jnp.split(kv, [qk_nope], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (qk_rope,))], axis=-1)
    scale = (qk_nope + qk_rope) ** -0.5
    if impl == "full":
        o = attend_full(q, k, v, scale=scale)
    else:
        o = attend_chunked(q, k, v, chunk=chunk, scale=scale)
    return jnp.einsum("bshd,hdm->bsm", o, params["wo"])


def mla_decode_layer(params, x, cache_ckv, cache_krope, position, kv_len,
                     rope_theta: float = 10000.0):
    """Absorbed-weight decode against the COMPRESSED cache.

    cache_ckv: (B,T,kv_lora)  cache_krope: (B,T,qk_rope).
    Attention runs entirely in latent space: per-head scores are
    q_nope·W_uk against c_kv, plus the shared rope term; the value read is
    the latent itself, decompressed once per layer.
    """
    kv_lora, h, qk_nope, qk_rope, v_head = _mla_dims(params)
    pos = position[:, None] if position.ndim == 1 else position
    q_nope, q_rope = mla_project_q(params, x, pos, rope_theta,
                                   qk_nope, qk_rope)
    c_kv, k_rope = mla_compress_kv(params, x, pos, rope_theta, kv_lora)

    t = cache_ckv.shape[1]
    b = cache_ckv.shape[0]
    bidx = jnp.arange(b)
    # in-place latent-cache scatter (see attention._scatter_kv)
    ckv = cache_ckv.at[bidx, kv_len].set(
        c_kv[:, 0].astype(cache_ckv.dtype), mode="drop")
    krope = cache_krope.at[bidx, kv_len].set(
        k_rope[:, 0].astype(cache_krope.dtype), mode="drop")

    w_uk = params["wkv_b"][:, :, :qk_nope]            # (R,H,Dn)
    w_uv = params["wkv_b"][:, :, qk_nope:]            # (R,H,Dv)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)[:, 0]  # (B,H,R)
    scale = (qk_nope + qk_rope) ** -0.5
    logits = (jnp.einsum("bhr,btr->bht", q_abs.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bhk,btk->bht", q_rope[:, 0].astype(jnp.float32),
                           krope.astype(jnp.float32))) * scale
    mask = jnp.arange(t)[None] < (kv_len + 1)[:, None]
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    lat = jnp.einsum("bht,btr->bhr", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", lat, w_uv.astype(jnp.float32))
    out = jnp.einsum("bhd,hdm->bm", o.astype(x.dtype), params["wo"])
    return out[:, None, :], ckv, krope
