"""xLSTM blocks: mLSTM (matrix memory, parallel-trainable) and sLSTM
(scalar memory, strict recurrence) — Beck et al. 2024.

mLSTM's parallel form is attention-like with an input-gate/forget-gate
decay matrix D[t,s] = i_s + sum_{s<r<=t} log f_r, stabilized by the
running row max; decode keeps an (N_k, N_v) matrix memory per head with
O(1)/token updates — the second ``long_500k``-capable family.

sLSTM is a genuine recurrence (lax.scan over time) with exponential
gating and a normalizer state, as in the paper.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, rmsnorm, rmsnorm_spec, swiglu, swiglu_spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_spec(d_model: int, n_heads: int) -> Dict[str, ParamSpec]:
    dh = d_model // n_heads
    return {
        "wq": ParamSpec((d_model, n_heads, dh), ("embed", "heads", None)),
        "wk": ParamSpec((d_model, n_heads, dh), ("embed", "heads", None)),
        "wv": ParamSpec((d_model, n_heads, dh), ("embed", "heads", None)),
        "wi": ParamSpec((d_model, n_heads), ("embed", "heads"), scale=0.02),
        "wf": ParamSpec((d_model, n_heads), ("embed", "heads"), scale=0.02),
        "bi": ParamSpec((n_heads,), ("heads",), init="zeros"),
        "bf": ParamSpec((n_heads,), ("heads",), init="ones"),
        "wo": ParamSpec((n_heads, dh, d_model), ("heads", None, "embed")),
        "norm": ParamSpec((n_heads, dh), ("heads", None), init="ones"),
    }


def _mlstm_gates(params, x):
    i = jnp.einsum("bsd,dh->bsh", x, params["wi"]) + params["bi"]
    f = jnp.einsum("bsd,dh->bsh", x, params["wf"]) + params["bf"]
    return i.astype(jnp.float32), jax.nn.log_sigmoid(f.astype(jnp.float32))


def mlstm_parallel(params, x):
    """Parallel (quadratic) mLSTM over a sequence. x:(B,S,D)."""
    b, s, d = x.shape
    h = params["wi"].shape[1]
    dh = d // h
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"])
    i, logf = _mlstm_gates(params, x)                  # (B,S,H)
    cumf = jnp.cumsum(logf, axis=1)                    # (B,S,H)
    # D[t,s] = i_s + cumf_t - cumf_s  (s <= t)
    dmat = (i + (-cumf))[:, None, :, :] + cumf[:, :, None, :]  # (B,T,S,H)
    dmat = jnp.moveaxis(dmat, -1, 1)                   # (B,H,T,S)
    mask = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(mask[None, None], dmat, NEG_INF)
    m = dmat.max(axis=-1, keepdims=True)               # (B,H,T,1)
    scores = jnp.einsum("bhtk,bhsk->bhts", q, k,
                        preferred_element_type=jnp.float32) * (dh ** -0.5)
    a = scores * jnp.exp(dmat - m)
    denom = jnp.maximum(jnp.abs(a.sum(-1, keepdims=True)),
                        jnp.exp(-m))                   # paper's max(|n|,1) scaled
    aw = (a / denom).astype(v.dtype)
    hid = jnp.einsum("bhts,bhsk->bhtk", aw, v,
                     preferred_element_type=jnp.float32)  # (B,H,S,Dh)
    hid = rmsnorm({"scale": params["norm"].reshape(-1)},
                  hid.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
                  .reshape(b, s, h * dh)).reshape(b, s, h, dh)
    return jnp.einsum("bshk,hkd->bsd", hid.astype(x.dtype), params["wo"])


def mlstm_init_cache(params, batch: int):
    h = params["wi"].shape[1]
    dh = params["wq"].shape[2]
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),  # matrix memory
        "n": jnp.zeros((batch, h, dh), jnp.float32),      # normalizer
        # -1e30 = "empty": exp(m_prev - m_new) underflows to 0 so the
        # empty state contributes nothing (matches the parallel form).
        "m": jnp.full((batch, h), -1e30, jnp.float32),    # stabilizer
    }


def mlstm_chunked(params, x, *, chunk: int = 1024, carry=None):
    """Chunked mLSTM: quadratic only within L-token chunks, the (K,V)
    matrix memory carried across chunks — flash-linear-attention
    dataflow, O(S·L) instead of O(S²) HBM traffic, and the enabler for
    long-context xLSTM training.

    Returns (out (B,S,D), carry {C,n,m}) — carry == the decode cache.
    """
    b, s, d = x.shape
    h = params["wi"].shape[1]
    dh = d // h
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"])
    i, logf = _mlstm_gates(params, x)                  # (B,S,H) f32
    if carry is None:
        carry = mlstm_init_cache(params, b)

    def split(t):                                      # (B,H,S,K)->(nc,B,H,L,K)
        return jnp.moveaxis(t.reshape(b, h, nc, l, -1), 2, 0)

    qc, kc, vc = split(q), split(k), split(v)
    ic = jnp.moveaxis(i.reshape(b, nc, l, h), 1, 0)    # (nc,B,L,H)
    fc = jnp.moveaxis(logf.reshape(b, nc, l, h), 1, 0)
    scale = dh ** -0.5
    tri = jnp.tril(jnp.ones((l, l), bool))

    def body(state, inp):
        qb, kb, vb, ib, fb = inp                       # (B,H,L,K)/(B,L,H)
        cS, nS, mS = state["C"], state["n"], state["m"]
        ib = jnp.moveaxis(ib, -1, 1)                   # (B,H,L)
        fb = jnp.moveaxis(fb, -1, 1)
        cum = jnp.cumsum(fb, axis=-1)                  # (B,H,L)
        # intra-chunk log weights D[t,s] = i_s + cum_t - cum_s
        dmat = ib[:, :, None, :] + cum[:, :, :, None] - cum[:, :, None, :]
        dmat = jnp.where(tri[None, None], dmat, NEG_INF)
        # inter log weight of the carried state at step t
        w = cum + mS[..., None]                        # (B,H,L)
        m_t = jnp.maximum(dmat.max(-1), w)             # (B,H,L)
        intra = jnp.exp(dmat - m_t[..., None])
        scores = jnp.einsum("bhtk,bhsk->bhts", qb, kb,
                            preferred_element_type=jnp.float32) * scale
        a = scores * intra
        wexp = jnp.exp(w - m_t)                        # (B,H,L)
        num = jnp.einsum("bhts,bhsv->bhtv", a.astype(vb.dtype), vb,
                         preferred_element_type=jnp.float32) \
            + wexp[..., None] * jnp.einsum(
                "bhtk,bhkv->bhtv", qb.astype(jnp.float32) * scale, cS)
        den = a.sum(-1) + wexp * jnp.einsum(
            "bhtk,bhk->bht", qb.astype(jnp.float32) * scale, nS)
        hid = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- carry update (telescoped decode recursion) ----
        tot = cum[..., -1]                             # (B,H)
        wk = ib + tot[..., None] - cum                 # (B,H,L) per-key log w
        m_new = jnp.maximum(mS + tot, wk.max(-1))
        kw = jnp.exp(wk - m_new[..., None])            # (B,H,L)
        c_new = jnp.exp(mS + tot - m_new)[..., None, None] * cS + \
            jnp.einsum("bhs,bhsk,bhsv->bhkv", kw,
                       kc_f32(kb), kc_f32(vb))
        n_new = jnp.exp(mS + tot - m_new)[..., None] * nS + \
            jnp.einsum("bhs,bhsk->bhk", kw, kc_f32(kb))
        return {"C": c_new, "n": n_new, "m": m_new}, hid

    def kc_f32(t):
        return t.astype(jnp.float32)

    carry, hids = jax.lax.scan(body, carry, (qc, kc, vc, ic, fc))
    hid = jnp.moveaxis(hids, 0, 2).reshape(b, h, s, dh)  # (B,H,S,Dh)
    hid = rmsnorm({"scale": params["norm"].reshape(-1)},
                  hid.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
                  ).reshape(b, s, h, dh)
    out = jnp.einsum("bshk,hkd->bsd", hid.astype(x.dtype), params["wo"])
    return out, carry


def mlstm_decode(params, x, cache):
    """O(1) recurrent step. x:(B,1,D)."""
    b, _, d = x.shape
    h = params["wi"].shape[1]
    dh = d // h
    q = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wq"]).astype(jnp.float32)
    k = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wk"]).astype(jnp.float32)
    v = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wv"]).astype(jnp.float32)
    i, logf = _mlstm_gates(params, x[:, :1])
    i, logf = i[:, 0], logf[:, 0]                      # (B,H)
    m_new = jnp.maximum(logf + cache["m"], i)
    decay = jnp.exp(logf + cache["m"] - m_new)[..., None]
    inp = jnp.exp(i - m_new)[..., None]
    c = cache["C"] * decay[..., None] + inp[..., None] * k[..., :, None] * v[..., None, :]
    n = cache["n"] * decay + inp * k
    num = jnp.einsum("bhk,bhkv->bhv", q * (dh ** -0.5), c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q * (dh ** -0.5), n)),
                      jnp.exp(-m_new))
    hid = num / den[..., None]
    hid = rmsnorm({"scale": params["norm"].reshape(-1)},
                  hid.reshape(b, h * dh)).reshape(b, h, dh)
    out = jnp.einsum("bhk,hkd->bd", hid.astype(x.dtype), params["wo"])
    return out[:, None], {"C": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(d_model: int, n_heads: int) -> Dict[str, ParamSpec]:
    dh = d_model // n_heads
    return {
        # input weights for gates z, i, f, o
        "wx": ParamSpec((d_model, 4, n_heads, dh), ("embed", None, "heads", None)),
        # block-diagonal recurrent weights per head
        "rh": ParamSpec((4, n_heads, dh, dh), (None, "heads", None, None),
                        scale=0.02),
        "b": ParamSpec((4, n_heads, dh), (None, "heads", None), init="zeros"),
        "norm": ParamSpec((n_heads, dh), ("heads", None), init="ones"),
        "wo": ParamSpec((n_heads, dh, d_model), ("heads", None, "embed")),
    }


def slstm_init_cache(params, batch: int):
    _, h, dh, _ = params["rh"].shape
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((batch, h, dh), jnp.float32)}


def _slstm_cell(params, state, xg):
    """xg: (B,4,H,Dh) pre-computed input contribution."""
    c, n, hprev, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhd,ghde->bghe", hprev, params["rh"].astype(jnp.float32))
    g = xg.astype(jnp.float32) + rec + params["b"].astype(jnp.float32)[None]
    zt = jnp.tanh(g[:, 0])
    it = g[:, 1]                                        # exp gate (log space)
    ft = jax.nn.log_sigmoid(g[:, 2])                    # forget in log space
    ot = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(ft + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    c_new = f_ * c + i_ * zt
    n_new = f_ * n + i_
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_layer(params, x):
    """Recurrent sLSTM over a sequence via lax.scan. x:(B,S,D)."""
    b, s, d = x.shape
    _, h, dh, _ = params["rh"].shape
    xg = jnp.einsum("bsd,dghe->bsghe", x, params["wx"])  # (B,S,4,H,Dh)
    state = slstm_init_cache(params, b)

    def body(st, xg_t):
        st = _slstm_cell(params, st, xg_t)
        return st, st["h"]

    state, hs = jax.lax.scan(body, state, jnp.moveaxis(xg, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                         # (B,S,H,Dh)
    hs = rmsnorm({"scale": params["norm"].reshape(-1)},
                 hs.reshape(b, s, h * dh)).reshape(b, s, h, dh)
    return jnp.einsum("bshk,hkd->bsd", hs.astype(x.dtype), params["wo"])


def slstm_decode(params, x, cache):
    b = x.shape[0]
    xg = jnp.einsum("bd,dghe->bghe", x[:, 0], params["wx"])
    st = _slstm_cell(params, cache, xg)
    h = params["rh"].shape[1]
    dh = params["rh"].shape[2]
    hs = rmsnorm({"scale": params["norm"].reshape(-1)},
                 st["h"].reshape(b, h * dh)).reshape(b, h, dh)
    out = jnp.einsum("bhk,hkd->bd", hs.astype(x.dtype), params["wo"])
    return out[:, None], st
