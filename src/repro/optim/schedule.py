"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, base_lr: float, total_steps: int,
                    min_ratio: float = 0.1):
    t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    return base_lr * (min_ratio + (1 - min_ratio) * 0.5
                      * (1 + jnp.cos(jnp.pi * t)))


def linear_warmup_cosine(step, base_lr: float, warmup_steps: int,
                         total_steps: int, min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    warm = base_lr * s / max(warmup_steps, 1)
    cos = cosine_schedule(step - warmup_steps, base_lr,
                          max(total_steps - warmup_steps, 1), min_ratio)
    return jnp.where(s < warmup_steps, warm, cos)
