from .store import (AsyncCheckpointer, CheckpointManager, latest_step,
                    restore, save)
