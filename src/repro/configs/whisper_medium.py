"""Whisper-medium backbone — enc-dec, conv frontend STUB.
[arXiv:2212.04356; unverified]

24 encoder + 24 decoder layers, d_model 1024, 16 heads, d_ff 4096,
vocab 51865.  The assigned seq_len is ENCODER frames (precomputed frame
embeddings from the stub frontend); decoder capped at 448 tokens.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_dec_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, dec_len=448,
    subquadratic=False,
)
