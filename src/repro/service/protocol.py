"""Typed request/response envelopes for the pricing service.

The wire contract of :class:`~repro.service.server.PricingService`,
following the shape of vLLM's ``serving_engine.py`` protocol layer: every
submission is a typed request dataclass; every outcome — including
failures — comes back as a :class:`Response` envelope carrying the
request id, timing, and either a result payload or a typed
:class:`ErrorInfo`.  A request NEVER raises into a sibling: errors are
enveloped per request and the tick loop keeps serving.

Request types (all priced through the fused ``repro.dse`` kernels and
therefore bit-exact against direct :class:`ChunkedEvaluator` /
``portfolio_search`` calls):

* :class:`PriceRequest`    — price a candidate index/object list.
* :class:`RankRequest`     — price + rank a candidate set (or the whole
  space), return the top-k with materialized labels.
* :class:`MCRiskRequest`   — Monte-Carlo risk sweep over candidates.
* :class:`WhatIfRequest`   — packaging/node deltas around a base
  candidate (the Tang & Xie-style "what if we used InFO instead of MCM
  at 5nm?" grid).
* :class:`SearchRequest`   — evolutionary portfolio search, advanced one
  jitted generation per tick so long searches interleave with point
  queries.
* :class:`PriceSystemsRequest` — price a raw ``spec()`` dict list (no
  DesignSpace needed), coalesced into a fixed padded engine batch.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..dse.evaluate import CandidateResult, EvalArrays
from ..dse.search import RiskConfig, SearchResult
from ..dse.space import Candidate
from ..dse.uncertainty import Uncertainty
from ..resilience.guards import nonfinite_paths

# Typed error codes (the closed set clients may dispatch on).
QUEUE_FULL = "queue_full"            # backpressure: bounded queue rejected
INVALID_REQUEST = "invalid_request"  # failed validation at admission
INTERNAL_ERROR = "internal"          # tick-time failure, isolated per request
DEADLINE_EXCEEDED = "deadline_exceeded"  # deadline_ms elapsed before done
NUMERICAL_ERROR = "numerical_error"  # non-finite cost in this request's rows
SHUTTING_DOWN = "shutting_down"      # drain deadline hit / service stopping


def mint_trace_id() -> str:
    """Mint a request trace id at admission: 16 hex chars, unique per
    process for all practical purposes.  The id is *durable* — it rides
    the journal's wire records and search checkpoints, so the response
    to a crash-replayed request carries the SAME trace_id the original
    admission minted, and one id correlates the whole causal chain:
    admission -> journal -> (crash, replay) -> coalesced ticks ->
    terminal envelope."""
    return os.urandom(8).hex()


@dataclasses.dataclass(frozen=True)
class ErrorInfo:
    """Typed error envelope — returned, never raised across requests."""

    code: str
    message: str


@dataclasses.dataclass(frozen=True)
class Timing:
    """Per-request latency surface (seconds, service-relative)."""

    submit_s: float            # absolute submit timestamp (perf_counter)
    first_result_s: float      # submit -> first coalesced rows on host
    done_s: float              # submit -> response ready


@dataclasses.dataclass(frozen=True)
class McSpec:
    """Monte-Carlo configuration of a risk sweep.

    ``(draws, quantiles)`` are static jit signature components — keep
    them on the service's warmed menu (``ServiceConfig.warm_mc``) so the
    hot path never recompiles; ``seed``/``sigmas`` are traced arguments
    and coalesce freely among requests that share them.
    """

    draws: int = 128
    quantiles: Tuple[float, ...] = (0.5, 0.9)
    seed: int = 0
    sigmas: Uncertainty = dataclasses.field(default_factory=Uncertainty)


@dataclasses.dataclass(frozen=True)
class PriceRequest:
    """Price a candidate list: indices (fast path) or Candidate objects."""

    indices: Optional[Sequence[int]] = None
    candidates: Tuple[Candidate, ...] = ()
    flow: str = "chip-last"
    mc: Optional[McSpec] = None      # attach risk stats to every row
    deadline_ms: Optional[float] = None  # wall budget; see validate_request

    kind = "price"


@dataclasses.dataclass(frozen=True)
class RankRequest:
    """Price + rank a candidate set; ``indices=None`` ranks the whole
    space.  Ties rank by candidate index (deterministic)."""

    indices: Optional[Sequence[int]] = None
    top_k: int = 10
    flow: str = "chip-last"
    mc: Optional[McSpec] = None      # rank on a risk stat instead of cost
    objective: str = "cost"          # "cost" or a risk key (e.g. "q90")
    deadline_ms: Optional[float] = None

    kind = "rank"


@dataclasses.dataclass(frozen=True)
class MCRiskRequest:
    """Monte-Carlo risk sweep: per-candidate quantiles under common
    random numbers (same scenarios for every candidate)."""

    indices: Sequence[int] = ()
    mc: McSpec = dataclasses.field(default_factory=McSpec)
    flow: str = "chip-last"
    deadline_ms: Optional[float] = None

    kind = "mc_risk"


@dataclasses.dataclass(frozen=True)
class WhatIfRequest:
    """Packaging/node what-if grid around ``base``: re-price the same
    architecture under every (process, integration) combination and
    report deltas vs the base.  Empty axes default to the space's menus;
    combinations outside the space are reported in ``skipped``, not
    errored."""

    base: Union[Candidate, int] = 0
    processes: Tuple[str, ...] = ()
    integrations: Tuple[str, ...] = ()
    flow: str = "chip-last"
    deadline_ms: Optional[float] = None

    kind = "what_if"


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """Evolutionary portfolio search (see ``repro.dse.portfolio_search``
    — same semantics, same determinism in ``seed``), served one jitted
    generation step per tick."""

    seed: int = 0
    population: int = 32
    generations: int = 12
    elite: int = 6
    jump_prob: float = 0.15
    risk: Optional[RiskConfig] = None
    flow: str = "chip-last"
    deadline_ms: Optional[float] = None  # checked between generations too

    kind = "search"


@dataclasses.dataclass(frozen=True)
class PriceSystemsRequest:
    """Price a raw system ``spec()`` dict list (one co-produced
    ``share_nre`` group, like ``SystemBatch.from_specs``); no DesignSpace
    membership required.  The group is priced in one tick (NRE amortizes
    across the group), so it must fit the service's raw-lane budget."""

    specs: Tuple[Dict[str, Any], ...] = ()
    flow: str = "chip-last"
    deadline_ms: Optional[float] = None

    kind = "price_systems"


Request = Union[PriceRequest, RankRequest, MCRiskRequest, WhatIfRequest,
                SearchRequest, PriceSystemsRequest]


# ---------------------------------------------------------------------------
# Result payloads
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RankResult:
    """Top-k of a ranked candidate set."""

    objective: str
    order: np.ndarray                  # (n,) candidate indices, best first
    values: np.ndarray                 # (n,) objective values, sorted
    top: List[CandidateResult]         # materialized top-k (labels etc.)


@dataclasses.dataclass
class WhatIfResult:
    """Per-(process, integration) re-pricing of the base architecture."""

    base_label: str
    base_cost: float
    rows: List[Dict]                   # label/process/integration/cost/delta
    skipped: List[Dict]                # combos outside the space + reason


@dataclasses.dataclass
class SystemsResult:
    """Per-system engine totals for a raw spec-list group."""

    rows: List[Dict]                   # name / re / nre / total / quantity


@dataclasses.dataclass
class Response:
    """The one answer envelope: ``ok`` + result, or a typed error."""

    request_id: int
    kind: str
    ok: bool
    result: Optional[Union[EvalArrays, RankResult, WhatIfResult,
                           SearchResult, SystemsResult]] = None
    error: Optional[ErrorInfo] = None
    timing: Optional[Timing] = None
    cached: bool = False               # served from the result cache
    # Degraded-mode provenance: True when any row of this response was
    # priced through the legacy host-packing fallback instead of the
    # fused path.  For row-sweep kinds ("price"/"mc_risk"),
    # degraded_rows is the (K,) bool per-row mask; degraded values are
    # float32 casts of the legacy oracle's float64s (slow-but-correct).
    degraded: bool = False
    degraded_rows: Optional[np.ndarray] = None
    # Replay provenance: True when this response answers a request that
    # was re-admitted from the durable journal after a crash/restart.
    # ``replayed_from`` is the ORIGINAL admission uid (stable across
    # replay chains), so clients can correlate with pre-crash ids.
    replayed: bool = False
    replayed_from: Optional[int] = None
    # Request-scoped trace id (see mint_trace_id): set on EVERY envelope
    # the service emits — ok, cached, degraded, replayed, and typed
    # errors alike — and stable across crash replay.
    trace_id: str = ""
    # The request's finalized serving-cost bill (obs.ledger.Bill.as_dict):
    # pro-rated device ms, rows priced, padded waste, cache/degraded/
    # replay provenance.  None only when the service ran without a ledger.
    bill: Optional[Dict] = None

    @property
    def latency_s(self) -> float:
        return self.timing.done_s if self.timing else 0.0


def validate_request(req: Request) -> Optional[str]:
    """Admission-time numerical validation; returns a problem string (the
    caller owes an ``invalid_request`` envelope) or None.

    Walks every numeric field of the request — including nested specs,
    McSpec sigmas, and candidate objects — and rejects NaN/Inf before
    they can reach a fused kernel and contaminate coalesced siblings.
    Also rejects non-positive ``deadline_ms`` (a deadline that can never
    be met is a client bug, not a ``deadline_exceeded`` outcome).
    """
    problems = nonfinite_paths(req, path=getattr(req, "kind", "request"))
    if problems:
        return "non-finite numeric field(s): " + "; ".join(problems)
    deadline = getattr(req, "deadline_ms", None)
    if deadline is not None and deadline <= 0:
        return f"deadline_ms must be positive, got {deadline}"
    return None


def error_response(request_id: int, kind: str, code: str, message: str,
                   t_submit: float = 0.0, trace_id: str = "") -> Response:
    now = time.perf_counter()
    dt = max(0.0, now - t_submit) if t_submit else 0.0
    return Response(request_id=request_id, kind=kind, ok=False,
                    error=ErrorInfo(code=code, message=message),
                    timing=Timing(submit_s=t_submit, first_result_s=dt,
                                  done_s=dt),
                    trace_id=trace_id)


# ---------------------------------------------------------------------------
# Request logging (vLLM serving_engine-style)
# ---------------------------------------------------------------------------


class RequestLog:
    """Structured per-request event log.

    Mirrors vLLM's ``RequestLogger``: every admission/completion/error is
    one event with the request id and a compact summary — queryable in
    tests via :meth:`records` and mirrored to the ``repro.service``
    :mod:`logging` channel (DEBUG) for operators."""

    def __init__(self, keep: int = 1024,
                 logger: Optional[logging.Logger] = None):
        self.keep = int(keep)
        self.logger = logger or logging.getLogger("repro.service")
        self._records: List[Dict] = []

    def event(self, request_id: int, event: str, **fields):
        rec = {"t": time.perf_counter(), "request_id": int(request_id),
               "event": event, **fields}
        self._records.append(rec)
        if len(self._records) > self.keep:
            del self._records[:len(self._records) - self.keep]
        self.logger.debug("req %d %s %s", request_id, event, fields)

    def records(self, request_id: Optional[int] = None,
                event: Optional[str] = None) -> List[Dict]:
        return [r for r in self._records
                if (request_id is None or r["request_id"] == request_id)
                and (event is None or r["event"] == event)]
