"""Family-dispatch API: one uniform surface over the whole model zoo.

The launch / serving / benchmark layers only ever touch:

  param_spec(cfg)                 ParamSpec tree of the model
  loss_fn(cfg)(params, batch)     scalar loss           [train_* shapes]
  prefill_fn(cfg)(params, batch)  (last_logits, cache)  [prefill_* shapes]
  decode_fn(cfg)(params, token, cache, kv_len)          [decode_* shapes]
  input_spec(cfg, shape)          ParamSpec dict of batch inputs
  cache_spec(cfg, shape)          ParamSpec tree of the decode cache
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax.numpy as jnp

from ..configs.base import ArchConfig, InputShape
from .common import ParamSpec
from . import encdec as ed
from . import transformer as tf


def param_spec(cfg: ArchConfig):
    if cfg.family == "encdec":
        return ed.encdec_spec(cfg)
    return tf.lm_spec(cfg)


def loss_fn(cfg: ArchConfig) -> Callable:
    if cfg.family == "encdec":
        return lambda params, batch: ed.encdec_loss(cfg, params, batch)
    return lambda params, batch: tf.lm_loss(cfg, params, batch)


def prefill_fn(cfg: ArchConfig, cache_len: int) -> Callable:
    """cache_len is static (the KV cache capacity to allocate)."""
    if cfg.family == "encdec":
        def _encdec_prefill(params, batch):
            cache = ed.encdec_prefill(cfg, params, batch["frames"])
            b = batch["frames"].shape[0]
            bos = jnp.zeros((b, 1), jnp.int32)
            logits, cache = ed.encdec_decode(cfg, params, bos, cache,
                                             jnp.zeros((b,), jnp.int32))
            return logits, cache
        return _encdec_prefill
    if cfg.family == "vlm":
        return lambda params, batch: tf.lm_prefill(
            cfg, params, batch["tokens"], cache_len,
            img_embeds=batch.get("img_embeds"))
    return lambda params, batch: tf.lm_prefill(
        cfg, params, batch["tokens"], cache_len)


def decode_fn(cfg: ArchConfig) -> Callable:
    if cfg.family == "encdec":
        return lambda params, token, cache, kv_len: (
            ed.encdec_decode(cfg, params, token, cache, kv_len))
    return lambda params, token, cache, kv_len: (
        tf.lm_decode(cfg, params, token, cache, kv_len))


def cache_spec(cfg: ArchConfig, shape: InputShape):
    if cfg.family == "encdec":
        return ed.encdec_cache_spec(cfg, shape.global_batch, shape.seq_len)
    return tf.decode_cache_spec(cfg, shape.global_batch, shape.seq_len)


def input_spec(cfg: ArchConfig, shape: InputShape) -> Dict[str, ParamSpec]:
    """ShapeDtypeStruct-able description of the batch for one cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = ("batch", "seq")
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": ParamSpec((b, s, cfg.d_model),
                                    ("batch", "seq", "act_embed"),
                                    cfg.jdtype),
                "dec_tokens": ParamSpec((b, cfg.dec_len), tok, jnp.int32),
                "labels": ParamSpec((b, cfg.dec_len), tok, jnp.int32),
            }
        if cfg.family == "vlm":
            p = min(cfg.n_img_patches, s // 2)
            return {
                "tokens": ParamSpec((b, s - p), tok, jnp.int32),
                "img_embeds": ParamSpec((b, p, cfg.d_model),
                                        ("batch", "seq", "act_embed"),
                                        cfg.jdtype),
                "labels": ParamSpec((b, s - p), tok, jnp.int32),
            }
        return {"tokens": ParamSpec((b, s), tok, jnp.int32),
                "labels": ParamSpec((b, s), tok, jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": ParamSpec((b, s, cfg.d_model),
                                        ("batch", "seq", "act_embed"),
                                        cfg.jdtype)}
        if cfg.family == "vlm":
            p = min(cfg.n_img_patches, s // 2)
            return {"tokens": ParamSpec((b, s - p), tok, jnp.int32),
                    "img_embeds": ParamSpec((b, p, cfg.d_model),
                                            ("batch", "seq", "act_embed"),
                                            cfg.jdtype)}
        return {"tokens": ParamSpec((b, s), tok, jnp.int32)}
    if shape.kind == "decode":
        return {"token": ParamSpec((b, 1), tok, jnp.int32),
                "kv_len": ParamSpec((b,), ("batch",), jnp.int32)}
    raise ValueError(shape.kind)
