"""Fault-tolerant checkpointing: async, atomic, sharded, elastic.

Layout per step:

  <dir>/step_000100.tmp-<nonce>/   (written)
  <dir>/step_000100/               (atomic rename when complete)
      manifest.json                (tree structure, shapes, dtypes, hash)
      arrays.npz                   (flat leaves by index)

* save() is synchronous; AsyncCheckpointer runs it on a background
  thread (train loop never blocks on I/O) with a bounded queue.
* restore() validates the manifest and RESHARDS onto whatever mesh the
  new process runs (elastic restore: the mesh shape may have changed
  between runs — arrays are loaded full and re-committed with the target
  shardings).
* retention keeps the newest K checkpoints; incomplete .tmp dirs are
  ignored by latest_step() => crash-safe.

On a real multi-host cluster each host would write its own shard files;
the manifest/atomic-rename/restore protocol is identical (single-process
transport here, interfaces real).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
import time
import uuid
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: Path, step: int, tree: Any,
         extra: Optional[Dict] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    digest = hashlib.sha256()
    for i in range(len(leaves)):
        digest.update(arrays[f"a{i}"].tobytes())
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "sha256": digest.hexdigest(),
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                 # atomic publish
    return final


def latest_step(directory: Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and ".tmp-" not in p.name \
                and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: Path, step: int, like: Any,
            shardings: Any = None, validate_hash: bool = True) -> Any:
    """Load step into the structure of `like`; optionally re-shard.

    `shardings` (same tree, NamedSharding leaves) commits each array to
    the CURRENT mesh — this is the elastic-restore path: a checkpoint
    written on one mesh shape restores onto any other.
    """
    d = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")
    leaves, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(f"leaf count mismatch: ckpt {manifest['n_leaves']}"
                         f" vs target {len(leaves)}")
    if validate_hash:
        digest = hashlib.sha256()
        for i in range(len(leaves)):
            digest.update(np.asarray(data[f"a{i}"]).tobytes())
        if digest.hexdigest() != manifest["sha256"]:
            raise ValueError("checkpoint hash mismatch (corrupt?)")
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.asarray(data[f"a{i}"])
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        if sh is not None:
            out.append(jax.device_put(arr.astype(ref.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr.astype(ref.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Retention + auto-resume glue.

    :meth:`restore_latest` is corruption-tolerant: a retained step whose
    manifest digest no longer matches its arrays (bit rot, torn copy) is
    skipped — counted in ``corrupt_fallbacks`` — and the previous
    retained step is restored instead of raising through.  Only when
    every retained step is unreadable does the error surface."""

    def __init__(self, directory: Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self.corrupt_fallbacks = 0

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        path = save(self.directory, step, tree, extra)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_")
            and ".tmp-" not in p.name)
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
        # sweep orphaned tmp dirs (crash mid-write)
        for p in self.directory.iterdir():
            if ".tmp-" in p.name:
                shutil.rmtree(p, ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def steps(self) -> List[int]:
        """Complete (published) steps on disk, oldest first."""
        if not self.directory.exists():
            return []
        return sorted(
            int(p.name.split("_")[1])
            for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_")
            and ".tmp-" not in p.name
            and (p / "manifest.json").exists())

    def restore_latest(self, like: Any, shardings: Any = None):
        """Restore the newest readable retained step (digest-verified).

        A corrupt step falls back to the previous retained one instead
        of raising; ``(None, None)`` when no step exists, and the last
        step's error re-raises only when *every* retained step is
        unreadable."""
        steps = self.steps()
        if not steps:
            return None, None
        last_err: Optional[Exception] = None
        for s in reversed(steps):
            try:
                return s, restore(self.directory, s, like, shardings)
            except Exception as e:  # noqa: BLE001 - any corruption mode
                self.corrupt_fallbacks += 1
                last_err = e
        raise ValueError(
            f"no readable checkpoint among steps {steps} in "
            f"{self.directory}") from last_err


class AsyncCheckpointer:
    """Background-thread checkpoint writer with a bounded queue.

    `submit` snapshots the (device) tree to host memory synchronously
    (cheap) and enqueues the serialization; training continues while the
    previous checkpoint is still being written.  `wait()` drains.
    """

    def __init__(self, manager: CheckpointManager, max_pending: int = 2):
        self.manager = manager
        self.q: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self.errors: List[BaseException] = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self.q.get()
            if item is None:
                self.q.task_done()
                return
            step, host_tree, extra = item
            try:
                self.manager.save(step, host_tree, extra)
            except BaseException as e:   # surfaced on wait()
                self.errors.append(e)
            finally:
                self.q.task_done()

    def submit(self, step: int, tree: Any, extra: Optional[Dict] = None):
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self.q.put((step, host_tree, extra))

    def wait(self):
        self.q.join()
        if self.errors:
            raise self.errors[0]

    def close(self):
        self.q.put(None)
        self.q.join()
